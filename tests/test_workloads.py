"""repro.workloads: every suite entry builds a valid traced Program whose
compiled (async, two-simulated-device) outputs match its pure-JAX
reference <=1e-5 on the small presets, plus preset/registry plumbing and
the mark_output trace ergonomic the suite leans on."""
import numpy as np
import pytest

from repro.api import ops, trace
from repro.bench.pinned import PinnedDispatcher
from repro.runtime import (Dispatcher, Fingerprint, TuningCache,
                           seed_from_programs, variant_skews)
from repro.workloads import (SIZES, get_workload, suite_registry,
                             workload_names)

ALL = workload_names()


@pytest.fixture(scope="module")
def registry():
    return suite_registry()


def _two_seeded_devices(tmp_path, registry, programs):
    devices = {}
    for name, speed in [("d0", 1.0e9), ("d1", 0.8e9)]:
        fp = Fingerprint("sim", f"wl-{name}", 1, 1, ("float32",))
        cache = TuningCache(root=str(tmp_path / "devs"), fingerprint=fp)
        d = Dispatcher(registry=registry, cache=cache)
        seed_from_programs(d, programs, speed)
        devices[name] = d
    return devices


def test_registry_covers_five_diverse_workloads():
    assert len(ALL) >= 5
    assert {"image_pipeline", "mlp_block", "attention_block",
            "decode_microbatch", "mixed_dag"} <= set(ALL)
    for name in ALL:
        w = get_workload(name)
        assert set(SIZES) <= set(w.presets), f"{name} missing a preset"
    # diversity: the suite collectively exercises every registry kernel
    used = set().union(*(get_workload(n).kernels for n in ALL))
    assert used == {"matmul", "matvec", "conv2d", "maxpool", "blur",
                    "flash_attention"}


def test_unknown_workload_and_preset_raise():
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("ghost")
    with pytest.raises(KeyError, match="preset"):
        get_workload("mlp_block").build("colossal")


@pytest.mark.parametrize("name", ALL)
def test_build_is_deterministic_and_valid(name, registry):
    w = get_workload(name)
    b1 = w.build("small", registry=registry)
    b2 = w.build("small", registry=registry)
    assert b1.program == b2.program
    # declared kernel set matches the traced program
    assert b1.kernels_used == set(w.kernels)
    # programs re-check against the registry (abstract hooks agree)
    b1.program.check(registry)
    assert set(b1.bindings) == {s.name for s in b1.program.inputs}
    # medium presets build too (structure only; no execution)
    assert w.build("medium", registry=registry).n_nodes >= b1.n_nodes


@pytest.mark.parametrize("name", ALL)
def test_compiled_async_matches_reference(name, tmp_path, registry):
    """Acceptance: the full stack (trace -> comm-free EFT over two seeded
    sim devices -> buffer planning -> async executor) reproduces the pure-
    JAX reference <=1e-5 on every workload's small preset."""
    built = get_workload(name).build("small", registry=registry)
    devices = _two_seeded_devices(tmp_path, registry, [built.program])
    compiled = built.program.compile(devices=devices,
                                     bindings=built.bindings,
                                     executor="async")
    outs = compiled()
    outs = outs if isinstance(outs, tuple) else (outs,)
    refs = built.reference()
    assert len(outs) == len(refs)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)
    # and the async path agrees with the sequential reference bridge
    seq = compiled(_executor="sequential")
    seq = seq if isinstance(seq, tuple) else (seq,)
    for a, s in zip(outs, seq):
        assert np.array_equal(np.asarray(a), np.asarray(s))


def test_mixed_dag_outputs_include_interior_node(registry):
    """mark_output lets a consumed (interior) node be an output — the leaf
    rule alone could never return mixed_dag's root."""
    b = get_workload("mixed_dag").build("small", registry=registry)
    prog = b.program
    root = prog.outputs[-1]
    consumed = {d for n in prog.nodes for d in n.deps}
    assert root in consumed


def test_mark_output_validation(registry):
    import jax.numpy as jnp
    a = jnp.zeros((8, 8), jnp.float32)
    with trace(registry=registry) as tb:
        y = ops.blur(a)
    with trace(registry=registry) as other:
        z = ops.blur(a)
        # a ref from another trace is rejected
        with pytest.raises(ValueError, match="not a value of this trace"):
            other.mark_output(y)
        # inputs cannot be outputs
        lazy_in = other._by_id[id(a)]
        with pytest.raises(ValueError, match="program input"):
            other.mark_output(lazy_in)
        other.mark_output(z, z)                    # dedup
    assert other.program.outputs == (z.name,)


def test_variant_skews_winner_is_never_default():
    for kernel in ("matmul", "matvec", "blur", "flash_attention"):
        for n in (2, 3, 5):
            s = variant_skews(n, kernel)
            assert s.shape == (n,)
            assert int(np.argmin(s)) != 0          # default never wins
            assert s.min() == pytest.approx(1.0)
            assert s.max() == pytest.approx(2.0)
    assert variant_skews(1, "blur").tolist() == [1.0]
    # deterministic
    assert variant_skews(5, "blur").tolist() == \
        variant_skews(5, "blur").tolist()


def test_seeded_caches_make_pinned_modes_ordered(tmp_path, registry):
    """On seeded caches best <= default <= worst predicted time per node,
    with best strictly under worst for every multi-variant kernel."""
    built = get_workload("mixed_dag").build("small", registry=registry)
    fp = Fingerprint("sim", "ord", 1, 1, ("float32",))
    cache = TuningCache(root=str(tmp_path / "ord"), fingerprint=fp)
    seed_from_programs(Dispatcher(registry=registry, cache=cache),
                       [built.program], 1.0e9)
    modes = {m: PinnedDispatcher(registry=registry, cache=cache, mode=m)
             for m in ("best", "default", "worst")}
    for node in built.program.nodes:
        t = {m: d.predict_time(node.kernel, node.params)
             for m, d in modes.items()}
        assert t["best"] <= t["default"] + 1e-15
        assert t["best"] <= t["worst"] + 1e-15
        if len(registry.variants(node.kernel)) > 1:
            assert t["best"] < t["worst"]
