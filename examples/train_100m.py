"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
through the full production stack (data pipeline -> sharded train step ->
checkpointing -> metrics), on CPU.

    PYTHONPATH=src python examples/train_100m.py --steps 200
    PYTHONPATH=src python examples/train_100m.py --steps 10   # smoke
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.launch import train as train_launcher
from repro.configs.base import ArchConfig


def model_100m() -> ArchConfig:
    # yi-9b family shrunk to ~100M params: 12L, d=768, untied 32k vocab
    base = get_arch("yi-9b")
    return dataclasses.replace(
        base, name="yi-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = model_100m()
    from repro.models import build_model, module
    n = module.count_params(build_model(cfg).param_specs())
    print(f"[100m] {cfg.name}: {n/1e6:.1f}M params")

    # route through the production launcher (checkpoint/resume/monitoring)
    import repro.configs as configs
    configs.ARCHS[cfg.name] = cfg
    train_launcher.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq-len", str(args.seq_len),
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50", "--lr", "3e-4",
        "--metrics-out", "/tmp/repro_100m_metrics.json",
    ])


if __name__ == "__main__":
    main()
