"""The paper's §1 motivating example, end to end, through ``repro.api``.

Two independent matmuls, a CPU-class and a GPU-class device: the small one
must take the CPU so the GPU is free for the big one — a decision only
*absolute time* predictions enable.  Where the pre-API version hand-built
``KernelTask`` DAGs and hand-wrote the predict callable, the user-facing
code is now just trace -> compile: the tracer derives params from avals,
``predictor_from_runtime`` pulls absolute times out of each device's
tuning cache, and the earliest-finish-time scheduler does the rest.

    PYTHONPATH=src python examples/schedule_dag.py
"""
import numpy as np

from repro.api import ops, trace
from repro.core.scheduler import KernelTask
from repro.runtime import default_registry
from repro.runtime.simdev import fake_matmul_device

ROOT = "results/fake_devices"


def main():
    reg = default_registry(include=["matmul"])
    devices = {"cpu": fake_matmul_device(ROOT, "cpu-xeon", 1e9, reg),
               "gpu": fake_matmul_device(ROOT, "gpu-tesla", 1e11, reg)}

    rng = np.random.RandomState(0)
    small_a = rng.rand(100, 100).astype(np.float32)
    small_b = rng.rand(100, 100).astype(np.float32)
    big_a = rng.rand(1024, 1024).astype(np.float32)
    big_b = rng.rand(1024, 1024).astype(np.float32)

    with trace(registry=reg) as tb:
        small = ops.matmul(small_a, small_b)
        big = ops.matmul(big_a, big_b)
    compiled = tb.compile(devices=devices)

    for row in compiled.gantt():
        print(f"{row['task']:10s} -> {row['device']}  "
              f"[{row['start_s']*1e3:8.3f}ms, {row['finish_s']*1e3:8.3f}ms]")
    print(f"makespan: {compiled.makespan*1e3:.3f}ms")

    # per-kernel winners alone would send BOTH matmuls to the GPU
    t = {(n, d): disp.predict_time("matmul",
                                   reg.params_of("matmul", a, b))
         for n, (a, b) in [("small", (small_a, small_b)),
                           ("big", (big_a, big_b))]
         for d, disp in devices.items()}
    print(f"(per-kernel, the small matmul is also faster on the GPU: "
          f"{t[('small', 'gpu')]*1e3:.3f}ms vs cpu "
          f"{t[('small', 'cpu')]*1e3:.3f}ms — but the schedule keeps the "
          f"GPU free for the big one)")

    out_small, out_big = compiled()
    ref = small_a @ small_b
    assert float(np.max(np.abs(np.asarray(out_small) - ref))) < 1e-2
    assert compiled.device_of(small.name) == "cpu"
    assert compiled.device_of(big.name) == "gpu"

    # the traced program lowers to exactly the tasks the old hand-rolled
    # version built by hand
    tasks = tb.program.to_kernel_tasks()
    assert tasks[0] == KernelTask(small.name, "matmul",
                                  {"m": 100, "n": 100, "k": 100})


if __name__ == "__main__":
    main()
