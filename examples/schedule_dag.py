"""The paper's §1 motivating example, end to end.

Train NN+C predictors for matmul on a CPU-class and a GPU-class device,
then schedule a DAG with one small and one big matmul: the small one must
take the CPU so the GPU is free for the big one — a decision only absolute
time predictions enable.

    PYTHONPATH=src python examples/schedule_dag.py
"""

from repro.core.features import feature_vector
from repro.core.nnc import make_model, slice_features
from repro.core.scheduler import KernelTask, makespan, schedule
from repro.perfdata.datasets import Combo, generate, train_test_split

DEVICES = {"cpu": Combo("mm", "eigen", "xeon", True),
           "gpu": Combo("mm", "cuda_shared", "tesla", True)}


def train_predictors():
    models = {}
    for dev, combo in DEVICES.items():
        X, y, _ = generate(combo, n=500, seed=0)
        (trX, trY), _ = train_test_split(X, y)
        model, uses_c = make_model("nnc", X.shape[1],
                                   mm_cpu=(dev == "cpu"), epochs=15000)
        model.fit(slice_features(trX, uses_c), trY)
        models[dev] = (model, uses_c, combo.is_cpu)
    return models


def main():
    models = train_predictors()

    def predict(task: KernelTask, device: str) -> float:
        model, uses_c, is_cpu = models[device]
        x = feature_vector("mm", task.params,
                           n_threads=32 if is_cpu else None)
        return float(model.predict(slice_features(x[None], uses_c))[0])

    small = KernelTask("small_mm", "mm",
                       {"m": 100, "n": 100, "k": 100, "d1": 1.0, "d2": 1.0})
    big = KernelTask("big_mm", "mm",
                     {"m": 1024, "n": 1024, "k": 1024, "d1": 1.0, "d2": 1.0})
    assignments = schedule([small, big], predict, list(DEVICES))
    for name, a in assignments.items():
        print(f"{name:10s} -> {a.device}  "
              f"[{a.start*1e3:8.3f}ms, {a.finish*1e3:8.3f}ms]")
    print(f"makespan: {makespan(assignments)*1e3:.3f}ms")
    print(f"(per-kernel, the small matmul is also faster on the GPU: "
          f"{predict(small,'gpu')*1e3:.3f}ms vs cpu {predict(small,'cpu')*1e3:.3f}ms"
          f" — but the schedule keeps the GPU free for the big one)")
    assert assignments["big_mm"].device == "gpu"


if __name__ == "__main__":
    main()
