"""Beyond-paper: NN+C autotunes the framework's own attention schedule.

The variant axis is the flash-attention tile schedule (q_chunk, k_chunk);
runtimes are REAL measured wall-times of the chunked attention on this
host.  The lightweight predictor (<75 weights) picks a schedule for an
unseen shape; we report its regret vs exhaustive search — the paper's Fig 4
methodology pointed at our own kernels.

    PYTHONPATH=src python examples/autotune_attention.py
"""
import numpy as np

from repro.autotune.tuner import AttentionTuner, measure_schedule

TRAIN_SHAPES = [(1, 2, 512, 64), (1, 4, 512, 64), (2, 2, 1024, 64),
                (1, 2, 2048, 64), (1, 8, 1024, 32)]
TEST_SHAPE = (1, 4, 2048, 64)
SCHEDULES = [(q, k) for q in (128, 256, 512) for k in (256, 512, 1024)]


def main():
    tuner = AttentionTuner()
    print("collecting measured schedule timings (train shapes)...")
    X, y = tuner.collect(TRAIN_SHAPES, schedules=SCHEDULES)
    tuner.fit(X, y)
    print(f"predictor: {tuner.model.n_params} params")

    b, h, s, d = TEST_SHAPE
    chosen = tuner.best_schedule(b, h, s, d, schedules=SCHEDULES)
    rng = np.random.RandomState(1)
    truth = {sc: measure_schedule(b, h, s, d, *sc, rng=rng)
             for sc in SCHEDULES}
    best = min(truth, key=truth.get)
    default = (256, 1024)               # the framework's static default
    print(f"\ntest shape {TEST_SHAPE}:")
    for sc, t in sorted(truth.items(), key=lambda kv: kv[1]):
        mark = " <== chosen" if sc == chosen else (" (true best)" if sc == best else "")
        print(f"  qc={sc[0]:4d} kc={sc[1]:5d}: {t*1e3:7.1f}ms{mark}")
    print(f"chosen {chosen}: regret vs best "
          f"{truth[chosen]/truth[best]:.2f}x, speedup vs default "
          f"{truth[default]/truth[chosen]:.2f}x")


if __name__ == "__main__":
    main()
