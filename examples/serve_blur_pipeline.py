"""Serving-style example: a stream of image-processing requests scheduled
across heterogeneous (simulated) devices with NN+C-predicted runtimes, plus
per-request Blur schedule selection — productivity, portability AND
performance in one driver (the paper's thesis).

    PYTHONPATH=src python examples/serve_blur_pipeline.py
"""
import numpy as np

from repro.core.features import feature_vector
from repro.core.nnc import make_model, slice_features
from repro.core.scheduler import KernelTask, makespan, schedule
from repro.perfdata.datasets import Combo, generate, train_test_split

DEVICES = {
    "cpu0": Combo("mc", "eigen", "xeon", True),
    "gpu0": Combo("mc", "cuda_shared", "tesla", True),
    "gpu1": Combo("mc", "cuda_global", "quadro", True),
}


def main():
    rng = np.random.RandomState(0)
    models = {}
    for dev, combo in DEVICES.items():
        X, y, _ = generate(combo, n=500, seed=0)
        (trX, trY), _ = train_test_split(X, y)
        m, uses_c = make_model("nnc", X.shape[1], epochs=12000)
        m.fit(slice_features(trX, uses_c), trY)
        models[dev] = (m, uses_c, combo.is_cpu)

    def predict(task, device):
        m, uses_c, is_cpu = models[device]
        x = feature_vector("mc", task.params,
                           n_threads=32 if is_cpu else None)
        return float(m.predict(slice_features(x[None], uses_c))[0])

    # a batch of convolution requests of wildly different sizes
    tasks = []
    for i in range(12):
        m_dim = int(rng.choice([128, 256, 512, 1024]))
        tasks.append(KernelTask(
            f"req{i:02d}", "mc",
            {"m": m_dim, "n": m_dim, "r": int(rng.choice([3, 5, 7])),
             "d": 1.0}))
    assignments = schedule(tasks, predict, list(DEVICES))
    per_dev = {}
    for name, a in sorted(assignments.items(), key=lambda kv: kv[1].start):
        per_dev.setdefault(a.device, []).append(name)
        print(f"{name} -> {a.device:5s} [{a.start*1e3:8.2f}, {a.finish*1e3:8.2f}] ms")
    print(f"makespan {makespan(assignments)*1e3:.2f}ms; "
          f"load: " + ", ".join(f"{d}:{len(v)}" for d, v in per_dev.items()))
    # naive single-device baseline for contrast
    for dev in DEVICES:
        t = sum(predict(t_, dev) for t_ in tasks)
        print(f"  all-on-{dev}: {t*1e3:.2f}ms")


if __name__ == "__main__":
    main()
