"""Author -> export -> re-import -> compile -> run: the portability loop.

A workload DAG is traced once and saved as pure data (shapes, kernels,
params, value flow — no arrays, no weights).  A different process — here, a
different hardware setup: two fake devices with their own fingerprinted
tuning caches — loads the JSON, re-validates it against its live registry,
and compiles it under *its* predicted times.  Writes the two artifacts CI
uploads: the exported program JSON and the predicted-schedule Gantt CSV.

    PYTHONPATH=src python examples/program_compile.py
"""
import json
import os

import numpy as np

from repro.api import Program, ops, save_gantt_csv, trace
from repro.runtime import default_registry
from repro.runtime.simdev import fake_matmul_device

ROOT = "results/fake_devices"
PROGRAM_JSON = "results/program.json"
GANTT_CSV = "results/schedule_gantt.csv"


def author(reg) -> Program:
    """A chained workload: two independent matmuls feeding a third."""
    rng = np.random.RandomState(0)
    with trace(registry=reg) as tb:
        left = ops.matmul(rng.rand(100, 100).astype(np.float32),
                          rng.rand(100, 100).astype(np.float32))
        right = ops.matmul(rng.rand(1024, 100).astype(np.float32),
                           rng.rand(100, 100).astype(np.float32))
        ops.matmul(right, left)
    return tb.program


def main():
    os.makedirs("results", exist_ok=True)
    reg = default_registry(include=["matmul"])

    program = author(reg)
    program.save(PROGRAM_JSON)
    size = os.path.getsize(PROGRAM_JSON)
    print(f"exported {len(program.nodes)}-node program -> {PROGRAM_JSON} "
          f"({size} bytes)")

    # ...elsewhere, under different hardware: load, re-validate, compile
    devices = {"cpu": fake_matmul_device(ROOT, "cpu-xeon", 1e9, reg),
               "gpu": fake_matmul_device(ROOT, "gpu-tesla", 1e11, reg)}
    loaded = Program.load(PROGRAM_JSON, registry=reg)
    assert loaded == program
    compiled = loaded.compile(devices=devices)

    save_gantt_csv(compiled, GANTT_CSV)
    print(f"schedule ({compiled.makespan*1e3:.3f}ms makespan) -> {GANTT_CSV}")
    for row in compiled.gantt():
        print(f"  {row['task']:10s} {row['device']:4s} "
              f"[{row['start_s']*1e3:8.3f}ms, {row['finish_s']*1e3:8.3f}ms]")

    # the loaded program carries no data: bind fresh inputs and execute
    rng = np.random.RandomState(1)
    arrays = [rng.rand(*spec.shape).astype(spec.dtype)
              for spec in loaded.inputs]
    out = compiled(*arrays)
    ref = (arrays[2] @ arrays[3]) @ (arrays[0] @ arrays[1])
    err = float(np.max(np.abs(np.asarray(out) - ref)) / np.max(np.abs(ref)))
    print(f"executed: out {out.shape}, max rel err {err:.2e}")
    assert err < 1e-5
    assert json.load(open(PROGRAM_JSON))["schema"] == 1


if __name__ == "__main__":
    main()
