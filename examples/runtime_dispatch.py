"""End-to-end runtime dispatch: cold -> warm -> cross-process reload.

1. COLD: a fresh tuning cache forces measured dispatch — every variant of
   the blur kernel is timed (black-box protocol), rows are recorded, and
   the lightweight NN+C model is fitted and persisted.
2. WARM: the same shapes dispatch again — now every decision is a <75-weight
   prediction, no measurement; steady-state overhead is reported as a
   fraction of kernel wall time.
3. RELOAD: a second *process* opens the cache from disk and must make
   identical selections (the persisted model round-trips bit-exactly).

    PYTHONPATH=src python examples/runtime_dispatch.py
"""
import json
import os
import shutil
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

SHAPES = [(384, 384), (512, 384), (512, 512), (768, 512),
          (768, 768), (1024, 768), (1024, 1024), (1536, 1024)]
WARM_REPS = 25


def make_dispatcher(root):
    from repro.runtime import (Dispatcher, DispatchPolicy, TuningCache,
                               default_registry)
    return Dispatcher(
        registry=default_registry(include=["blur"]),
        cache=TuningCache(root=root),
        policy=DispatchPolicy(min_rows_to_fit=5 * len(SHAPES),
                              fit_epochs=6000))


def run_shapes(dispatcher, reps=1):
    rng = np.random.RandomState(0)
    selections = {}
    for (m, n) in SHAPES:
        a = jnp.asarray(rng.rand(m, n), jnp.float32)
        for _ in range(reps):
            dispatcher.dispatch("blur", a)
        sel = dispatcher.selections[-1]
        selections[f"{m}x{n}"] = sel.chosen
    return selections


def child_main(root):
    """Second process: reload the cache, dispatch, print selections."""
    d = make_dispatcher(root)
    print(json.dumps({"selections": run_shapes(d),
                      "measured": d.n_measured}))


def main():
    # dedicated demo root, cleared so the cold run is genuinely cold
    root = os.path.join("results", "tunecache-demo")
    shutil.rmtree(root, ignore_errors=True)
    d = make_dispatcher(root)

    print(f"== cold run (cache: {d.cache.dir}) ==")
    cold = run_shapes(d)
    print(f"dispatches: {d.stats()['dispatches']}, measured: {d.n_measured}, "
          f"predicted: {d.n_predicted}")
    if d._entry("blur").model is None:
        d.fit("blur")               # small shape set: fit explicitly
    for size, chosen in cold.items():
        print(f"  {size:10s} -> {chosen}")

    print("\n== warm run (same process) ==")
    run_shapes(d)                   # decision-memo warm-up pass
    d.reset_stats()                 # ...then measure the steady state
    n_measured_before = d.n_measured
    warm = run_shapes(d, reps=WARM_REPS)
    stats = d.stats()
    assert d.n_measured == n_measured_before, "warm run must not measure"
    for size, chosen in warm.items():
        print(f"  {size:10s} -> {chosen}")
    print(f"steady-state dispatch overhead: "
          f"{stats['steady_overhead_s']*1e6:.0f}us "
          f"= {stats['steady_overhead_pct']:.2f}% of wall time "
          f"(target <5%)")

    print("\n== second process reloads the cache ==")
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, __file__, "--child", root],
                         capture_output=True, text=True, env=env, check=True)
    child = json.loads(out.stdout.strip().splitlines()[-1])
    assert child["measured"] == 0, "child must dispatch purely from cache"
    assert child["selections"] == warm, (child["selections"], warm)
    print("child selections identical to warm run; 0 measurements — OK")

    overhead_ok = stats["steady_overhead_pct"] < 5.0
    print(f"\noverhead target met: {overhead_ok}")
    return 0 if overhead_ok else 1


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child":
        child_main(sys.argv[2])
    else:
        sys.exit(main())
