"""Quickstart: the whole pitch in five lines.

    from repro.api import ops, trace
    with trace() as tb:
        out = ops.blur(ops.matmul(a, b))   # lazy op graph — nothing runs
    compiled = tb.compile()                # schedule from predicted times
    result = compiled()                    # predicted-best variant per node

Demo 1 runs exactly that flow against this host's own tuning cache: a few
eager warm-up calls cold-measure the variants and fit the NN+C models,
then the traced graph compiles and executes prediction-only.  Demo 2 is
the paper's offline predictor study (train NN+C on a kernel/variant/
hardware combo, ~13% MAPE regime).  Demo 3 trains a reduced
assigned-architecture LM through the production train step — the
substrate the predictor drives.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.nnc import make_model, mape, slice_features
from repro.perfdata.datasets import Combo, generate, train_test_split


def api_demo():
    print("== 1. repro.api: trace -> compile -> run ==")
    from repro.api import ops, trace, use_dispatcher
    from repro.runtime import Dispatcher, DispatchPolicy

    disp = Dispatcher(policy=DispatchPolicy(
        min_rows_to_fit=6, fit_epochs=1500, min_window=1e-3))
    rng = np.random.RandomState(0)
    a = rng.rand(96, 80).astype(np.float32)
    b = rng.rand(80, 64).astype(np.float32)

    with use_dispatcher(disp):
        # eager calls are the same API — here they warm the tuning cache
        # (cold path measures variants, then the lightweight model fits)
        for m, n, k in [(64, 64, 64), (96, 80, 64), (128, 96, 80)]:
            ops.matmul(rng.rand(m, k).astype(np.float32),
                       rng.rand(k, n).astype(np.float32))
        for m, n in [(96, 96), (128, 96), (94, 62)]:
            ops.blur(rng.rand(m, n).astype(np.float32))

        with trace() as tb:
            out = ops.blur(ops.matmul(a, b))
        compiled = tb.compile()
        result = compiled()

    ref = np.asarray(a @ b)
    ref = (sum(ref[i:ref.shape[0] - 2 + i, j:ref.shape[1] - 2 + j]
               for i in range(3) for j in range(3)) / 9.0)
    print(f"traced program: {[n.name for n in tb.program.nodes]}, "
          f"predicted makespan {compiled.makespan*1e3:.3f}ms")
    for sel in list(disp.selections)[-2:]:
        print(f"  {sel.kernel:8s} -> {sel.chosen} ({sel.mode})")
    print(f"max|api - reference| = "
          f"{float(np.max(np.abs(np.asarray(result) - ref))):.2e} "
          f"(out {out.shape})")


def nnc_demo():
    print("\n== 2. NN+C performance prediction (mv / eigen / i7) ==")
    combo = Combo("mv", "eigen", "i7", simulated=True)
    X, y, names = generate(combo, n=500, seed=0, cache_dir=None)
    (trX, trY), (teX, teY) = train_test_split(X, y)
    model, uses_c = make_model("nnc", X.shape[1], epochs=12000)
    model.fit(slice_features(trX, uses_c), trY)
    pred = model.predict(slice_features(teX, uses_c))
    print(f"features: {names}")
    print(f"NN+C ({model.n_params} params): test MAPE "
          f"{mape(teY, pred):.1f}%  (paper regime: ~13%)")


def lm_demo():
    print("\n== 3. Reduced gemma3-1b through the production train step ==")
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.optim.adamw import AdamW
    from repro.train.step import TrainStepConfig, make_train_step
    from repro.data.pipeline import DataConfig, Pipeline

    cfg = get_arch("gemma3-1b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, TrainStepConfig(ce_seq_chunk=32)))
    pipe = Pipeline(DataConfig(cfg.vocab_size, seq_len=64, global_batch=4))
    for i in range(5):
        params, opt_state, metrics = step(params, opt_state, pipe.next_batch())
        print(f"step {i+1}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    api_demo()
    nnc_demo()
    lm_demo()
