"""Quickstart: the two halves of the framework in ~60 seconds.

1. Train an NN+C performance predictor on a kernel-variant-hardware combo
   and use it to select the fastest variant (the paper's contribution).
2. Train a (reduced) assigned-architecture LM for a few steps through the
   production train step (the substrate the predictor drives).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.nnc import make_model, mape, slice_features
from repro.perfdata.datasets import Combo, generate, train_test_split


def nnc_demo():
    print("== 1. NN+C performance prediction (mv / eigen / i7) ==")
    combo = Combo("mv", "eigen", "i7", simulated=True)
    X, y, names = generate(combo, n=500, seed=0, cache_dir=None)
    (trX, trY), (teX, teY) = train_test_split(X, y)
    model, uses_c = make_model("nnc", X.shape[1], epochs=12000)
    model.fit(slice_features(trX, uses_c), trY)
    pred = model.predict(slice_features(teX, uses_c))
    print(f"features: {names}")
    print(f"NN+C ({model.n_params} params): test MAPE "
          f"{mape(teY, pred):.1f}%  (paper regime: ~13%)")


def lm_demo():
    print("\n== 2. Reduced gemma3-1b through the production train step ==")
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.optim.adamw import AdamW
    from repro.train.step import TrainStepConfig, make_train_step
    from repro.data.pipeline import DataConfig, Pipeline

    cfg = get_arch("gemma3-1b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, TrainStepConfig(ce_seq_chunk=32)))
    pipe = Pipeline(DataConfig(cfg.vocab_size, seq_len=64, global_batch=4))
    for i in range(5):
        params, opt_state, metrics = step(params, opt_state, pipe.next_batch())
        print(f"step {i+1}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    nnc_demo()
    lm_demo()
