"""Asynchronous multi-device execution of a traced pipeline.

The full ``repro.exec`` story in one script: two simulated devices (their
tuning caches predict — and, via ``simulate_time``, *take* — honest
absolute times), a simulated inter-device link measured into a ``CommModel``
as tunecache pseudo-kernels, a traced fan-out/fan-in DAG compiled with
comm-aware EFT, and the same schedule executed twice — once through the
sequential reference bridge, once through the dependency-driven async
executor.  Prints the predicted vs actual timelines and writes the async
run's Chrome trace (chrome://tracing / Perfetto) next to the other CI
artifacts.

    PYTHONPATH=src python examples/async_pipeline.py
"""
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.api import ops, trace
from repro.exec import CommModel
from repro.runtime import TuningCache, default_registry
from repro.runtime.simdev import SimLink, fake_matmul_device

ROOT = "results/fake_devices"
TRACE_JSON = "results/exec_trace.json"
N = 192


def main():
    os.makedirs("results", exist_ok=True)
    reg = default_registry(include=["matmul"])
    devices = {
        "cpu": fake_matmul_device(ROOT, "pipe-cpu", 1.0e9, reg,
                                  simulate_time=True),
        "gpu": fake_matmul_device(ROOT, "pipe-gpu", 0.9e9, reg,
                                  simulate_time=True),
    }
    link = SimLink(latency_s=5e-4, bytes_per_s=2e9)
    comm = CommModel(TuningCache(root=os.path.join(ROOT, "comm")))
    link.measure_into(comm, [("cpu", "gpu"), ("gpu", "cpu")])
    print("link model (measured into the tunecache as pseudo-kernels):")
    for nbytes in (1 << 14, 1 << 20):
        print(f"  {nbytes:>8d} B: predicted "
              f"{comm.predict('cpu', 'gpu', nbytes)*1e3:.3f}ms, "
              f"true {link.seconds(nbytes)*1e3:.3f}ms")

    rng = np.random.RandomState(0)
    arrs = [jnp.asarray(rng.rand(N, N), jnp.float32) for _ in range(6)]
    with trace(registry=reg) as tb:
        root = ops.matmul(arrs[0], arrs[1])
        b0 = ops.matmul(root, arrs[2])       # four independent branches —
        b1 = ops.matmul(root, arrs[3])       # the async executor overlaps
        b2 = ops.matmul(root, arrs[4])       # them across the two devices
        b3 = ops.matmul(root, arrs[5])
        ops.matmul(ops.matmul(b0, b1), ops.matmul(b2, b3))

    compiled = tb.compile(devices=devices, executor="async", comm=comm,
                          transfer=link.transfer)
    print(f"\npredicted schedule ({compiled.makespan*1e3:.1f}ms makespan, "
          f"{len(compiled.transfers)} transfers):")
    for row in compiled.gantt():
        print(f"  {row['task']:10s} {row['device']:4s} "
              f"[{row['start_s']*1e3:7.1f}ms, {row['finish_s']*1e3:7.1f}ms]")
    for t in compiled.transfers:
        print(f"  {t.name} ({t.nbytes} B on lane {t.lane})")

    compiled(_executor="sequential")         # jit warmup outside the clocks
    t0 = time.perf_counter()
    out_seq = compiled(_executor="sequential")
    seq_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_async = compiled(_executor="async")
    async_wall = time.perf_counter() - t0

    assert np.array_equal(np.asarray(out_seq), np.asarray(out_async)), \
        "async must match the sequential reference bit-for-bit"
    compiled.last_trace.save_chrome(TRACE_JSON)

    print(f"\nsequential bridge: {seq_wall*1e3:7.1f}ms  (sum of nodes, "
          "no overlap)")
    print(f"async executor:    {async_wall*1e3:7.1f}ms  (predicted "
          f"{compiled.makespan*1e3:.1f}ms)")
    print(f"overlap speedup:   {seq_wall/async_wall:7.2f}x, outputs "
          "bit-identical")
    print(f"chrome trace -> {TRACE_JSON}")
    print("\nmeasured timeline (async):")
    print(compiled.last_trace.to_gantt_csv())


if __name__ == "__main__":
    main()
